"""Compressed uplinks + heterogeneous client ranks (DESIGN.md §12).

Covers the wire codec contract end to end: the sketch round-trip is
bitwise at full coverage, cold/gated rounds are bit-for-bit the dense
path, warm rounds engage the codec and cut ``bytes_up``, the
energy-fraction gate trips on planted basis drift, final accuracy stays
allclose to dense at k << d1*d2 across every method on both engines, the
per-client rank masks are the equal-uniform-rank zero-padding oracle by
construction, and the odd-cohort (nc=7) warm carry is fallback-free
under the ceil rank cap.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import METHODS, AggregatorConfig, aggregate
from repro.core import engine as engine_lib
from repro.core import rpca as rpca_lib
from repro.core.aggregators import rpca_diag_summary
from repro.core.engine import AggSession
from repro.fed import FedRunConfig, LocalSpec, run_simulation, synth
from repro.fed import partition as partition_lib
from repro.fed import sketch as sketch_lib
from repro.launch import costmodel
from repro.optim import make_optimizer


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def round_trees(rng, nc=8, rounds=4, drift=0.02):
    """Correlated multi-round deltas (drifting shared rank-2 core plus
    persistent sparse spikes) — the regime where the carried basis
    captures the bulk of each round's delta."""
    shapes = {"A": (4, 6, 8), "head": (12, 4)}
    cores, spikes = {}, {}
    for k, s in shapes.items():
        d = int(np.prod(s))
        cores[k] = (rng.normal(size=(d, 2)), rng.normal(size=(2, nc)))
        supp = rng.random((d, nc)) < 0.05
        spikes[k] = np.where(supp, 5.0 * rng.normal(size=(d, nc)), 0.0)
    out = []
    for _t in range(rounds):
        tree = {}
        for k, s in shapes.items():
            u, w = cores[k]
            w_t = w + drift * rng.normal(size=w.shape)
            sp_t = spikes[k] * (1.0 + 0.05 * rng.normal(size=spikes[k].shape))
            tree[k] = jnp.asarray((u @ w_t + sp_t).T.reshape(nc, *s), jnp.float32)
        out.append(tree)
    return out


def session_cfg(**kw):
    base = dict(
        method="fedrpca", rpca_iters=40, svt_mode="subspace",
        carry_mode="subspace",
    )
    base.update(kw)
    return AggregatorConfig(**base)


def tree_equal(a, b):
    return all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    )


# ---------------------------------------------------------------------------
# parse_uplink
# ---------------------------------------------------------------------------


class TestParseUplink:
    def test_defaults(self):
        assert sketch_lib.parse_uplink(None).mode == "dense"
        assert not sketch_lib.parse_uplink("dense").active
        c = sketch_lib.parse_uplink("sketch")
        assert c.active and c.k == sketch_lib.DEFAULT_K
        assert c.energy_tol == sketch_lib.DEFAULT_ENERGY_TOL

    def test_explicit(self):
        c = sketch_lib.parse_uplink("sketch:16:0.5")
        assert (c.mode, c.k, c.energy_tol) == ("sketch", 16, 0.5)
        assert sketch_lib.parse_uplink("sketch:16").k == 16

    def test_passthrough(self):
        c = sketch_lib.UplinkConfig(mode="sketch", k=8, energy_tol=0.1)
        assert sketch_lib.parse_uplink(c) is c

    @pytest.mark.parametrize("bad", [
        "dense:4", "sketch:0", "sketch:-1", "sketch:4:2.0", "sketch:4:-0.1",
        "sketch:4:0.1:9", "foo", "",
    ])
    def test_rejects(self, bad):
        with pytest.raises(ValueError):
            sketch_lib.parse_uplink(bad)


# ---------------------------------------------------------------------------
# Codec round-trip
# ---------------------------------------------------------------------------


class TestCodec:
    def _basis(self, rng, b, d1, r):
        raw = jnp.asarray(rng.normal(size=(b, d1, r)), jnp.float32)
        return rpca_lib._orthonormalize(raw)

    def test_roundtrip_bitwise_full_k(self, rng):
        """k = d1 ships every residual position's RAW entry, so decode
        overwrites the projection with the original bytes — bitwise."""
        m = jnp.asarray(rng.normal(size=(3, 24, 6)), jnp.float32)
        basis = self._basis(rng, 3, 24, 4)
        s = sketch_lib.encode_delta(m, basis, 24)
        m_hat = sketch_lib.decode_into_bucket(s, basis)
        assert np.array_equal(np.asarray(m_hat), np.asarray(m))
        # energy_frac is computed analytically (resid_sq - kept_sq), so
        # float summation order leaves epsilon residue even at full k.
        assert float(jnp.max(s.energy_frac)) < 1e-5

    def test_partial_k_energy_monotone(self, rng):
        m = jnp.asarray(rng.normal(size=(2, 32, 5)), jnp.float32)
        basis = self._basis(rng, 2, 32, 3)
        fracs = [
            float(jnp.max(sketch_lib.encode_delta(m, basis, k).energy_frac))
            for k in (2, 8, 16, 32)
        ]
        assert fracs == sorted(fracs, reverse=True)
        assert fracs[-1] == 0.0

    def test_pure_low_rank_exact(self, rng):
        """A delta living entirely in the carried basis reconstructs from
        the coefficients alone (fp32-allclose; top-k only sweeps noise)."""
        b, d1, c, r = 2, 40, 6, 3
        basis = self._basis(rng, b, d1, r)
        coef = jnp.asarray(rng.normal(size=(b, r, c)), jnp.float32)
        m = jnp.einsum("bdr,brc->bdc", basis, coef)
        s = sketch_lib.encode_delta(m, basis, 4)
        m_hat = sketch_lib.decode_into_bucket(s, basis)
        np.testing.assert_allclose(
            np.asarray(m_hat), np.asarray(m), atol=1e-5, rtol=1e-5
        )
        assert float(jnp.max(s.energy_frac)) < 1e-6

    def test_energy_frac_is_the_decode_error(self, rng):
        """The gate metric must be exactly what it claims: the per-module
        reconstruction error energy as a fraction of the delta energy —
        computed analytically on the encoder side, without a decode."""
        m = jnp.asarray(rng.normal(size=(3, 30, 5)), jnp.float32)
        basis = self._basis(rng, 3, 30, 4)
        s = sketch_lib.encode_delta(m, basis, 6)
        m_hat = sketch_lib.decode_into_bucket(s, basis)
        err = np.asarray(m_hat - m, np.float64)
        want = (err**2).sum(axis=(1, 2)) / (np.asarray(m, np.float64)**2).sum(
            axis=(1, 2)
        )
        np.testing.assert_allclose(
            np.asarray(s.energy_frac, np.float64), want, atol=1e-5, rtol=1e-3
        )

    def test_byte_model(self):
        # The bench geometry (2 modules of vec 1024, basis rank 8, k=64):
        # sketch must beat dense by >= 4x, the perf-gate bar.
        dense = sketch_lib.dense_bytes_per_client([1024] * 2)
        sk = sketch_lib.sketch_bytes_per_client(2, 8, 64)
        assert dense / sk >= 4.0
        assert sketch_lib.basis_bytes(4, 512, 4) == 4 * 4 * 512 * 4


# ---------------------------------------------------------------------------
# Engine gate: cold/tripped rounds are bitwise the dense path
# ---------------------------------------------------------------------------


class TestEngineGate:
    def _run(self, trees, uplink=None):
        cfg = session_cfg()
        plan = engine_lib.plan_aggregation(trees[0], cfg, uplink=uplink)
        carry = engine_lib.init_agg_carry(plan)
        outs, scalars = [], []
        for t in trees:
            out, carry, diag = engine_lib.aggregate_planned(
                plan, t, carry, with_diagnostics=True
            )
            outs.append(jax.tree_util.tree_map(np.asarray, out))
            scalars.append(
                {k: float(v) for k, v in rpca_diag_summary(diag).items()}
            )
        return outs, scalars

    def test_dense_mode_is_the_no_codec_plan(self, rng):
        trees = round_trees(rng, rounds=2)
        cfg = session_cfg()
        assert engine_lib.plan_aggregation(trees[0], cfg, uplink="dense").uplink is None
        assert engine_lib.plan_aggregation(trees[0], cfg, uplink=None).uplink is None

    def test_cold_round_bitwise_dense(self, rng):
        """Round 0 has no carried basis -> the gate trips -> the sketch
        plan's output is bit-for-bit the dense plan's."""
        trees = round_trees(rng, rounds=1)
        dense, _ = self._run(trees)
        sk, sc = self._run(trees, uplink="sketch:8:0.9")
        assert tree_equal(dense[0], sk[0])
        assert sc[0]["uplink_hit_rate"] == 0.0
        assert sc[0]["uplink_dense_falls"] >= 1.0

    def test_zero_tol_gates_every_round_bitwise(self, rng):
        """energy_tol=0 can never accept a lossy sketch, so the whole
        multi-round session is bit-for-bit the dense session."""
        trees = round_trees(rng, rounds=3)
        dense, _ = self._run(trees)
        sk, sc = self._run(trees, uplink="sketch:8:0.0")
        for d, s in zip(dense, sk):
            assert tree_equal(d, s)
        assert all(s["uplink_hit_rate"] == 0.0 for s in sc)

    def test_warm_rounds_engage_and_cut_bytes(self, rng):
        trees = round_trees(rng, rounds=4)
        _, sc = self._run(trees, uplink="sketch:16:0.9")
        assert sc[0]["uplink_hit_rate"] == 0.0  # cold
        assert all(s["uplink_hit_rate"] == 1.0 for s in sc[1:])
        dense_bytes = sc[0]["bytes_up"]
        assert all(s["bytes_up"] < dense_bytes for s in sc[1:])

    def test_gate_trips_on_planted_basis_drift(self, rng):
        """Warm the carry on one subspace, then feed a round drawn from a
        fresh core: the residual energy blows past the tolerance and that
        round degrades to dense — while an aligned round sketches."""
        trees = round_trees(rng, rounds=3)
        aligned = trees[2]
        drifted = round_trees(np.random.default_rng(99), rounds=1)[0]

        cfg = session_cfg()
        plan = engine_lib.plan_aggregation(trees[0], cfg, uplink="sketch:8:0.3")
        carry = engine_lib.init_agg_carry(plan)
        for t in trees[:2]:
            _, carry, _ = engine_lib.aggregate_planned(
                plan, t, carry, with_diagnostics=True
            )

        _, _, diag_a = engine_lib.aggregate_planned(
            plan, aligned, carry, with_diagnostics=True
        )
        assert float(rpca_diag_summary(diag_a)["uplink_hit_rate"]) == 1.0

        out_d, _, diag_d = engine_lib.aggregate_planned(
            plan, drifted, carry, with_diagnostics=True
        )
        assert float(rpca_diag_summary(diag_d)["uplink_hit_rate"]) == 0.0
        # The tripped round is bit-for-bit the dense plan fed the same
        # carry state.
        plan_dense = engine_lib.plan_aggregation(trees[0], cfg)
        out_ref, _, _ = engine_lib.aggregate_planned(
            plan_dense, drifted, carry, with_diagnostics=True
        )
        assert tree_equal(out_ref, out_d)


# ---------------------------------------------------------------------------
# Odd-cohort rank cap (the nc=7 warm-carry fallback fix)
# ---------------------------------------------------------------------------


class TestOddCohortRankCap:
    def test_subspace_rank_ceil(self):
        assert rpca_lib.subspace_rank(7, 8) == 4
        assert rpca_lib.subspace_rank(9, 8) == 5
        assert rpca_lib.subspace_rank(8, 8) == 4
        assert rpca_lib.subspace_rank(2, 8) == 1
        assert rpca_lib.subspace_rank(1, 8) == 1
        assert rpca_lib.subspace_rank(16, 3) == 3  # rank cap still binds

    def test_true_cols_caps_below_padded_width(self):
        assert rpca_lib.subspace_rank(8, 8, true_cols=5) == 3
        assert rpca_lib.subspace_rank(8, 8, true_cols=8) == 4
        assert rpca_lib.subspace_rank(8, 8, true_cols=1) == 1

    @pytest.mark.parametrize("nc", [7, 9])
    def test_odd_cohort_warm_fallback_free(self, nc, rng):
        """The documented nc=7 failure mode: under the floor cap (r=3) the
        planted rank-2-plus-spikes workload saturated the carried width and
        every warm round fell back to eigh.  The ceil cap (r=4) leaves
        headroom — warm rounds run fallback-free, like even cohorts."""
        trees = round_trees(rng, nc=nc, rounds=4)
        sess = AggSession(session_cfg())
        falls = []
        for t in trees:
            _, diag = sess.step(t)
            falls.append(int(diag.scalars["fallback_count"]))
        assert all(f == 0 for f in falls[1:]), falls

    def test_costmodel_matches_engine_cap(self):
        """costmodel's analytic r must track rpca.subspace_rank exactly
        (both sides of the ceil fix), visible through the sketch byte
        model: bytes scale with r."""
        for cohort in (2, 5, 7, 8, 9, 16):
            r_engine = rpca_lib.subspace_rank(cohort, 8)
            got = costmodel.uplink_costs(
                n_modules=1, padded_vec=256, cohort=cohort, svt_rank=8, k=16,
            )
            want = 1 * (r_engine * 4 + 16 * 8)
            assert got["sketch_bytes_per_client"] == want, (cohort, r_engine)
            assert costmodel.mesh_agg_costs(
                n_modules=2, padded_vec=64, cohort=cohort, shards=1,
            )["us"] > 0


# ---------------------------------------------------------------------------
# Simulation parity: every method x both engines, sketch vs dense
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def sim_task():
    return synth.make_synth_task(
        n_clients=8, n_per_client=24, d_in=32, d_feat=32, alpha=0.4, seed=3
    )


def _sim_cfg(method, engine, rounds=3, **kw):
    agg_kw = dict(method=method, rpca_iters=8)
    if method == "fedrpca" and engine == "packed":
        agg_kw.update(svt_mode="subspace", carry_mode="subspace")
    defaults = dict(
        aggregator=AggregatorConfig(**agg_kw),
        local=LocalSpec(
            loss_fn=lambda base, lora, batch: synth.loss_fn(
                base, lora, batch, 2.0
            ),
            optimizer=make_optimizer("adam", 1e-2),
            local_steps=2,
            batch_size=8,
            lr=1e-2,
        ),
        rounds=rounds,
        engine=engine,
    )
    defaults.update(kw)
    return FedRunConfig(**defaults)


def _run_sim(task, cfg):
    eval_fn = lambda lora: synth.accuracy(
        task.base, lora, task.test_x, task.test_y, task.lora_scale
    )
    logs = []
    with warnings.catch_warnings():
        # Non-carrying combos degrade sketch -> dense with a warning; the
        # degradation itself is what the parity assertions check.
        warnings.simplefilter("ignore")
        lora, hist = run_simulation(
            task.base, synth.init_lora(task), task.client_x, task.client_y,
            cfg, eval_fn, log_fn=lambda r, d: logs.append(d),
        )
    return lora, hist, logs


class TestSimulationParity:
    @pytest.mark.parametrize("engine", ["packed", "reference"])
    @pytest.mark.parametrize("method", sorted(METHODS))
    def test_sketch_matches_dense_accuracy(self, method, engine, sim_task):
        """--uplink sketch:8 (k << d1*d2) lands within fp32-allclose of the
        dense run's final accuracy for every method on both engines.  Only
        the carrying packed fedrpca path actually sketches; every other
        combo degrades to dense and must match bit-for-bit."""
        dense_cfg = _sim_cfg(method, engine)
        sketch_cfg = _sim_cfg(method, engine, uplink="sketch:8:0.9")
        lora_d, hist_d, _ = _run_sim(sim_task, dense_cfg)
        lora_s, hist_s, logs_s = _run_sim(sim_task, sketch_cfg)
        sketches = method == "fedrpca" and engine == "packed"
        if sketches:
            assert any(d.get("uplink_hit_rate", 0.0) > 0.0 for d in logs_s)
            np.testing.assert_allclose(hist_s[-1], hist_d[-1], atol=0.01)
        else:
            assert tree_equal(lora_d, lora_s)
            np.testing.assert_array_equal(hist_d, hist_s)

    def test_sketch_pipeline_runs(self, sim_task):
        cfg = _sim_cfg(
            "fedrpca", "packed", uplink="sketch:8:0.9",
            pipeline=True, staleness=2,
        )
        _, hist, logs = _run_sim(sim_task, cfg)
        assert np.isfinite(hist).all()
        assert all("bytes_up" in d for d in logs)


# ---------------------------------------------------------------------------
# Wire byte counters
# ---------------------------------------------------------------------------


class TestWireCounters:
    def test_counters_logged_every_round(self, sim_task):
        _, _, logs = _run_sim(sim_task, _sim_cfg("fedavg", "packed"))
        assert logs and all(
            d["bytes_up"] > 0 and d["bytes_down"] > 0 for d in logs
        )

    def test_sketch_cuts_bytes_up(self, sim_task):
        _, _, dense_logs = _run_sim(sim_task, _sim_cfg("fedrpca", "packed"))
        _, _, sk_logs = _run_sim(
            sim_task, _sim_cfg("fedrpca", "packed", uplink="sketch:8:0.9")
        )
        dense_up = dense_logs[-1]["bytes_up"]
        warm = [d for d in sk_logs if d.get("uplink_hit_rate", 0.0) == 1.0]
        assert warm, "no warm sketch round engaged"
        assert all(d["bytes_up"] < dense_up for d in warm)
        # Sketch rounds pay the basis multicast on top of the model cast.
        assert all(d["bytes_down"] > dense_logs[-1]["bytes_down"] for d in warm)

    def test_costmodel_reduction(self):
        got = costmodel.uplink_costs(
            n_modules=2, padded_vec=1024, cohort=16, svt_rank=8, k=64,
        )
        assert got["reduction_vs_dense"] >= 4.0
        assert got["sketch_wins"]
        blended = costmodel.uplink_costs(
            n_modules=2, padded_vec=512, cohort=16, svt_rank=8, k=64,
            dense_rounds_frac=0.5,
        )
        assert blended["reduction_vs_dense"] < got["reduction_vs_dense"]
        assert blended["effective_bytes_per_client"] > got["effective_bytes_per_client"]


# ---------------------------------------------------------------------------
# Heterogeneous per-client ranks
# ---------------------------------------------------------------------------


class TestClientRanks:
    def test_parse_cycles_and_validates(self):
        got = partition_lib.parse_client_ranks("8,4", 5, 8)
        assert got.tolist() == [8, 4, 8, 4, 8]
        assert partition_lib.parse_client_ranks([2, 3], 3, 4).tolist() == [2, 3, 2]
        with pytest.raises(ValueError):
            partition_lib.parse_client_ranks("16", 4, 8)  # > template rank
        with pytest.raises(ValueError):
            partition_lib.parse_client_ranks("0,4", 4, 8)
        with pytest.raises(ValueError):
            partition_lib.parse_client_ranks("", 4, 8)
        with pytest.raises(ValueError):
            partition_lib.parse_client_ranks("a,b", 4, 8)

    def test_infer_lora_rank(self, sim_task):
        lora = synth.init_lora(sim_task)
        assert partition_lib.infer_lora_rank(lora) == sim_task.lora_rank
        with pytest.raises(ValueError):
            partition_lib.infer_lora_rank({"W": jnp.zeros((3, 3))})

    def test_masks_are_the_zero_padding_oracle(self, sim_task, rng):
        """mask * delta must equal the delta a rank-r_i client would ship
        zero-padded into the uniform layout: rank slices >= r_i exactly
        zero, slices < r_i bitwise untouched."""
        lora = synth.init_lora(sim_task)
        ranks = partition_lib.parse_client_ranks("4,2,1", 8, 4)
        masks = partition_lib.client_rank_masks(lora, ranks)
        deltas = jax.tree_util.tree_map(
            lambda x: jnp.asarray(
                rng.normal(size=(8,) + x.shape), jnp.float32
            ),
            lora,
        )
        masked = jax.tree_util.tree_map(
            lambda d, mk: d * mk.astype(d.dtype), deltas, masks
        )
        # Manual oracle: zero-pad each client's rank axis beyond rank_i.
        a = np.asarray(deltas["A"]).copy()  # (8, d_in, r)
        b = np.asarray(deltas["B"]).copy()  # (8, r, d_feat)
        for i, r in enumerate(ranks.tolist()):
            a[i, :, r:] = 0.0
            b[i, r:, :] = 0.0
        np.testing.assert_array_equal(np.asarray(masked["A"]), a)
        np.testing.assert_array_equal(np.asarray(masked["B"]), b)

    def test_masked_aggregation_is_rank_declaration_invariant(self, sim_task, rng):
        """Declared client_ranks are a descriptor: the aggregation of
        already-masked deltas is bitwise identical whether or not the plan
        knows the declaration (the equal-uniform-rank oracle equality)."""
        lora = synth.init_lora(sim_task)
        ranks = partition_lib.parse_client_ranks("4,2", 8, 4)
        masks = partition_lib.client_rank_masks(lora, ranks)
        deltas = jax.tree_util.tree_map(
            lambda x: jnp.asarray(
                rng.normal(size=(8,) + x.shape), jnp.float32
            ),
            lora,
        )
        masked = jax.tree_util.tree_map(
            lambda d, mk: d * mk.astype(d.dtype), deltas, masks
        )
        cfg = session_cfg(rpca_iters=10)
        plan_plain = engine_lib.plan_aggregation(masked, cfg)
        plan_decl = engine_lib.plan_aggregation(
            masked, cfg, client_ranks=ranks.tolist()
        )
        assert plan_decl.spec.client_ranks == tuple(ranks.tolist())
        out_plain, _, _ = engine_lib.aggregate_planned(
            plan_plain, masked, engine_lib.init_agg_carry(plan_plain),
            with_diagnostics=True,
        )
        out_decl, _, _ = engine_lib.aggregate_planned(
            plan_decl, masked, engine_lib.init_agg_carry(plan_decl),
            with_diagnostics=True,
        )
        assert tree_equal(out_plain, out_decl)

    def test_full_rank_declaration_is_a_bitwise_noop(self, sim_task):
        """client_ranks all equal to the template rank multiplies every
        delta by exactly 1.0 — IEEE-exact, so the run is bit-for-bit the
        undeclared run."""
        cfg_plain = _sim_cfg("fedrpca", "packed")
        cfg_full = _sim_cfg("fedrpca", "packed", client_ranks="4")
        lora_p, hist_p, _ = _run_sim(sim_task, cfg_plain)
        lora_f, hist_f, _ = _run_sim(sim_task, cfg_full)
        assert tree_equal(lora_p, lora_f)
        np.testing.assert_array_equal(hist_p, hist_f)

    def test_hetero_ranks_run_end_to_end(self, sim_task):
        cfg = _sim_cfg(
            "fedrpca", "packed", client_ranks="4,2,1",
            uplink="sketch:8:0.9",
        )
        lora, hist, logs = _run_sim(sim_task, cfg)
        assert np.isfinite(hist).all()
        assert all(np.isfinite(np.asarray(l)).all()
                   for l in jax.tree_util.tree_leaves(lora))
        assert all("bytes_up" in d for d in logs)
