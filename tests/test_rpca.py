"""Robust-PCA: recovery, SVT equivalence, and algebraic properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    batched_robust_pca,
    robust_pca,
    robust_pca_fixed_iters,
    soft_threshold,
    svt_gram,
    svt_svd,
)


def planted(n, m, rank, sparsity, scale=5.0, seed=0):
    rng = np.random.default_rng(seed)
    low = rng.normal(size=(n, rank)) @ rng.normal(size=(rank, m))
    sp = np.zeros((n, m))
    mask = rng.random((n, m)) < sparsity
    sp[mask] = scale * rng.normal(size=mask.sum())
    return low, sp


class TestSVT:
    @pytest.mark.parametrize("shape", [(64, 8), (8, 64), (128, 128), (33, 7)])
    def test_gram_matches_svd(self, shape, rng):
        x = jnp.asarray(rng.normal(size=shape), jnp.float32)
        for t in (0.0, 0.5, 3.0, 100.0):
            a, b = svt_gram(x, t), svt_svd(x, t)
            np.testing.assert_allclose(a, b, atol=2e-4, rtol=1e-3)

    def test_svt_zero_threshold_identity(self, rng):
        x = jnp.asarray(rng.normal(size=(50, 10)), jnp.float32)
        np.testing.assert_allclose(svt_gram(x, 0.0), x, atol=1e-4)

    def test_svt_large_threshold_zero(self, rng):
        x = jnp.asarray(rng.normal(size=(50, 10)), jnp.float32)
        np.testing.assert_allclose(svt_gram(x, 1e6), jnp.zeros_like(x), atol=1e-5)


class TestRPCA:
    def test_planted_recovery(self):
        low, sp = planted(512, 16, rank=2, sparsity=0.05)
        res = robust_pca(jnp.asarray(low + sp, jnp.float32), max_iter=500)
        assert res.residual < 1e-6
        assert np.linalg.norm(res.low_rank - low) / np.linalg.norm(low) < 0.08
        assert np.linalg.norm(res.sparse - sp) / np.linalg.norm(sp) < 0.12

    def test_reconstruction_invariant(self, rng):
        """M = L + S must hold at the stopping tolerance."""
        m = jnp.asarray(rng.normal(size=(128, 12)), jnp.float32)
        res = robust_pca(m, max_iter=300, tol=1e-6)
        resid = jnp.linalg.norm(m - res.low_rank - res.sparse) / jnp.linalg.norm(m)
        assert float(resid) < 1e-5

    def test_sparse_is_sparse(self):
        low, sp = planted(256, 16, rank=1, sparsity=0.03)
        res = robust_pca(jnp.asarray(low + sp, jnp.float32), max_iter=400)
        frac_nonzero = float(jnp.mean((jnp.abs(res.sparse) > 1e-3).astype(jnp.float32)))
        assert frac_nonzero < 0.15  # close to the 3% planted support

    def test_low_rank_is_low_rank(self):
        low, sp = planted(256, 16, rank=2, sparsity=0.03)
        res = robust_pca(jnp.asarray(low + sp, jnp.float32), max_iter=400)
        s = jnp.linalg.svd(res.low_rank, compute_uv=False)
        energy_top2 = float(jnp.sum(s[:2] ** 2) / jnp.maximum(jnp.sum(s**2), 1e-12))
        assert energy_top2 > 0.95

    def test_fixed_iters_matches_whileloop(self, rng):
        m = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
        a = robust_pca_fixed_iters(m, n_iter=100)
        b = robust_pca(m, max_iter=100, tol=0.0)
        np.testing.assert_allclose(a.low_rank, b.low_rank, atol=1e-5)
        np.testing.assert_allclose(a.sparse, b.sparse, atol=1e-5)

    def test_batched(self, rng):
        ms = jnp.asarray(rng.normal(size=(5, 64, 8)), jnp.float32)
        res = batched_robust_pca(ms, n_iter=50)
        single = robust_pca_fixed_iters(ms[2], n_iter=50)
        np.testing.assert_allclose(res.low_rank[2], single.low_rank, atol=1e-5)

    def test_zero_matrix(self):
        m = jnp.zeros((32, 4), jnp.float32)
        res = robust_pca_fixed_iters(m, n_iter=10)
        assert np.all(np.isfinite(res.low_rank)) and np.all(np.isfinite(res.sparse))

    def test_jit_and_grad_safe(self, rng):
        m = jnp.asarray(rng.normal(size=(32, 4)), jnp.float32)
        out = jax.jit(lambda x: robust_pca_fixed_iters(x, n_iter=20).low_rank)(m)
        assert np.all(np.isfinite(out))


@settings(max_examples=20, deadline=None)
@given(
    t=st.floats(0.0, 5.0),
    n=st.integers(4, 60),
    m=st.integers(2, 12),
)
def test_soft_threshold_properties(t, n, m):
    rng = np.random.default_rng(42)
    x = jnp.asarray(rng.normal(size=(n, m)) * 3, jnp.float32)
    y = soft_threshold(x, t)
    # shrinkage: |y| <= max(|x| - t, 0), sign preserved or zeroed
    assert np.all(np.abs(y) <= np.maximum(np.abs(x) - t, 0) + 1e-6)
    assert np.all((y == 0) | (np.sign(y) == np.sign(x)))
    # 1-Lipschitz in t around 0: t=0 is identity
    np.testing.assert_allclose(soft_threshold(x, 0.0), x, atol=1e-7)
