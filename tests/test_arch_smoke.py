"""Per-assigned-architecture smoke tests (reduced family variants on CPU).

Each of the 10 assigned architectures instantiates a REDUCED config of the
same family (<= 2 pattern units, d_model <= 512, <= 4 experts) and runs one
forward + one federated train step, asserting output shapes and no NaNs.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfglib
from repro.core import AggregatorConfig
from repro.launch import steps as steps_lib
from repro.models import forward, init_lora_params, init_params, loss_fn
from repro.utils.pytree import tree_norm, tree_sub

ARCHS = list(cfglib.ARCH_IDS)


def reduced_batch(cfg, key, b=2, s=16):
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
    }
    if cfg.frontend == "vision":
        batch["vision_embeds"] = jax.random.normal(
            key, (b, cfg.n_vision_tokens, cfg.d_model), jnp.dtype(cfg.dtype)
        )
    if cfg.frontend == "audio":
        batch["encoder_frames"] = jax.random.normal(
            key, (b, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype)
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_reduced_constraints(self, arch):
        cfg = cfglib.get_config(arch).reduced()
        assert cfg.d_model <= 512
        assert cfg.n_experts <= 4
        assert cfg.n_layers <= 2 * max(len(cfg.layer_pattern), 1) + len(cfg.layer_pattern)

    def test_forward_step(self, arch):
        cfg = cfglib.get_config(arch).reduced()
        key = jax.random.PRNGKey(0)
        params = init_params(key, cfg)
        lora = init_lora_params(key, cfg)
        batch = reduced_batch(cfg, key)
        logits, _, _ = forward(params, lora, batch, cfg, mode="train", remat=False)
        assert logits.shape == (2, 16, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits, np.float32)).all(), arch

    def test_fed_train_step(self, arch):
        """One federated round (2 clients, FedRPCA) moves the global LoRA."""
        cfg = cfglib.get_config(arch).reduced()
        key = jax.random.PRNGKey(1)
        base = init_params(key, cfg)
        lora = init_lora_params(key, cfg)
        m, per, s = 2, 2, 16
        batch = {
            "tokens": jax.random.randint(key, (m, per, s), 0, cfg.vocab_size),
            "labels": jax.random.randint(key, (m, per, s), 0, cfg.vocab_size),
        }
        if cfg.frontend == "vision":
            batch["vision_embeds"] = jax.random.normal(
                key, (m, per, cfg.n_vision_tokens, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        if cfg.frontend == "audio":
            batch["encoder_frames"] = jax.random.normal(
                key, (m, per, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        step = steps_lib.make_fed_train_step(
            cfg, AggregatorConfig(method="fedrpca", rpca_iters=10),
            local_lr=1e-3, local_steps=1, remat=False,
        )
        new_lora, metrics = step(base, lora, batch)
        assert np.isfinite(float(metrics["loss"]))
        moved = float(tree_norm(tree_sub(new_lora, lora)))
        assert moved > 0, f"{arch}: aggregation produced a zero update"
        for leaf in jax.tree_util.tree_leaves(new_lora):
            assert np.isfinite(np.asarray(leaf, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_fields(arch):
    """The full (assigned) configs match the assignment table."""
    cfg = cfglib.get_config(arch)
    table = {
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "mamba2-130m": (24, 768, None, None, 0, 50280),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
    }
    layers, d, h, kv, ff, vocab = table[arch]
    assert cfg.n_layers == layers and cfg.d_model == d
    assert cfg.d_ff == ff and cfg.vocab_size == vocab
    if h is not None:
        assert cfg.n_heads == h and cfg.n_kv_heads == kv
    assert cfg.source, "config must cite its source"
    if arch == "llama4-maverick-400b-a17b":
        assert cfg.n_experts == 128 and cfg.top_k == 1
    if arch == "granite-moe-1b-a400m":
        assert cfg.n_experts == 32 and cfg.top_k == 8
    if arch == "mamba2-130m":
        assert cfg.ssm_state == 128
    if arch == "gemma-7b":
        assert cfg.head_dim == 256
