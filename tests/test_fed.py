"""Federated runtime: local methods, server rounds, paper-claim directions."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AggregatorConfig
from repro.fed import FedRunConfig, LocalSpec, rounds_to_reach, run_simulation, synth
from repro.fed.client import make_local_fn
from repro.optim import make_optimizer
from repro.utils.pytree import tree_norm, tree_sub, tree_zeros_like


@pytest.fixture(scope="module")
def task():
    return synth.make_synth_task(n_clients=12, n_per_client=48, alpha=0.3, seed=1)


def spec_for(task, **kw):
    loss = lambda base, lora, batch: synth.loss_fn(base, lora, batch, task.lora_scale)
    defaults = dict(
        loss_fn=loss,
        optimizer=make_optimizer("adam", 1e-2),
        local_steps=6,
        batch_size=24,
        lr=1e-2,
        feature_fn=lambda base, lora, x: synth.features(base, lora, x, task.lora_scale),
    )
    defaults.update(kw)
    return LocalSpec(**defaults)


def run(task, method="fedavg", rounds=15, seed=0, spec=None, **agg_kw):
    cfg = FedRunConfig(
        aggregator=AggregatorConfig(method=method, rpca_iters=40, **agg_kw),
        local=spec or spec_for(task),
        rounds=rounds,
        seed=seed,
    )
    eval_fn = lambda lora: synth.accuracy(
        task.base, lora, task.test_x, task.test_y, task.lora_scale
    )
    return run_simulation(
        task.base, synth.init_lora(task), task.client_x, task.client_y, cfg, eval_fn
    )


class TestLocal:
    def test_fedprox_pulls_toward_global(self, task):
        base = task.base
        lora0 = synth.init_lora(task)
        zeros = tree_zeros_like(lora0)
        res = {}
        for mu in (0.0, 10.0):
            fn = make_local_fn(spec_for(task, fedprox_mu=mu))
            out = fn(base, lora0, task.client_x[0], task.client_y[0],
                     jax.random.PRNGKey(0), zeros, zeros, lora0)
            res[mu] = float(tree_norm(out.delta))
        assert res[10.0] < res[0.0]

    def test_scaffold_variates_update(self, task):
        lora0 = synth.init_lora(task)
        zeros = tree_zeros_like(lora0)
        fn = make_local_fn(spec_for(task, scaffold=True))
        out = fn(task.base, lora0, task.client_x[0], task.client_y[0],
                 jax.random.PRNGKey(0), zeros, zeros, lora0)
        assert float(tree_norm(out.new_ci)) > 0

    def test_moon_loss_finite(self, task):
        lora0 = synth.init_lora(task)
        zeros = tree_zeros_like(lora0)
        fn = make_local_fn(spec_for(task, moon_mu=1.0))
        out = fn(task.base, lora0, task.client_x[0], task.client_y[0],
                 jax.random.PRNGKey(0), zeros, zeros, lora0)
        assert np.isfinite(float(out.final_loss))


class TestSimulation:
    def test_fedavg_learns(self, task):
        _, hist = run(task, "fedavg", rounds=12)
        zero_shot = float(synth.accuracy(task.base, synth.init_lora(task),
                                         task.test_x, task.test_y, task.lora_scale))
        assert hist[-1] > zero_shot + 0.05

    def test_fedrpca_not_worse_than_fedavg(self, task):
        """Paper Table 1 direction (planted synthetic analogue)."""
        _, h_avg = run(task, "fedavg", rounds=15)
        _, h_rpca = run(task, "fedrpca", rounds=15)
        assert h_rpca[-1] >= h_avg[-1] - 0.01, (h_rpca[-1], h_avg[-1])

    def test_all_methods_run(self, task):
        for method in ("fedavg", "task_arithmetic", "ties", "fedrpca"):
            _, hist = run(task, method, rounds=3)
            assert np.isfinite(hist).all(), method

    def test_scaffold_composes_with_fedrpca(self, task):
        """Paper Fig. 5: client-level methods compose with the aggregator."""
        spec = spec_for(task, scaffold=True)
        _, hist = run(task, "fedrpca", rounds=4, spec=spec)
        assert np.isfinite(hist).all()

    def test_rounds_to_reach(self):
        hist = np.asarray([0.1, 0.5, 0.8, 0.85, 0.9])
        # target = 0.9 * 0.9 = 0.81; first round reaching it is #4 (0.85).
        assert rounds_to_reach(hist, 0.9) == 4


class TestPartition:
    def test_dirichlet_covers_all(self, rng):
        from repro.fed.partition import dirichlet_partition

        labels = rng.integers(0, 10, size=2000)
        parts = dirichlet_partition(labels, 8, alpha=0.3, rng=rng)
        joined = np.concatenate(parts)
        assert len(joined) == 2000 and len(np.unique(joined)) == 2000

    def test_lower_alpha_more_skew(self, rng):
        from repro.fed.partition import dirichlet_partition, label_distribution

        labels = rng.integers(0, 10, size=8000)

        def skew(alpha):
            parts = dirichlet_partition(labels, 10, alpha=alpha,
                                        rng=np.random.default_rng(0))
            dist = label_distribution(labels, parts, 10)
            return np.mean(np.max(dist, axis=1))  # avg dominant-class share

        assert skew(0.1) > skew(10.0)


class TestPartialParticipation:
    def test_subsampled_round_runs(self, task):
        from repro.fed import FedRunConfig
        from repro.core import AggregatorConfig

        cfg = FedRunConfig(
            aggregator=AggregatorConfig(method="fedrpca", rpca_iters=20),
            local=spec_for(task), rounds=4, seed=0, clients_per_round=5,
        )
        eval_fn = lambda lora: synth.accuracy(
            task.base, lora, task.test_x, task.test_y, task.lora_scale
        )
        _, hist = run_simulation(
            task.base, synth.init_lora(task), task.client_x, task.client_y, cfg, eval_fn
        )
        assert np.isfinite(hist).all()

    def test_subsampled_scaffold(self, task):
        from repro.fed import FedRunConfig
        from repro.core import AggregatorConfig

        cfg = FedRunConfig(
            aggregator=AggregatorConfig(method="fedavg"),
            local=spec_for(task, scaffold=True), rounds=3, seed=1,
            clients_per_round=4,
        )
        eval_fn = lambda lora: synth.accuracy(
            task.base, lora, task.test_x, task.test_y, task.lora_scale
        )
        _, hist = run_simulation(
            task.base, synth.init_lora(task), task.client_x, task.client_y, cfg, eval_fn
        )
        assert np.isfinite(hist).all()
